"""Compiled-artifact bundles: round-trip exactness + tamper rejection.

The bundle contract (ISSUE 3): ``save → load → api.build`` must be
bit-exact against both the freshly compiled engine and the DAIS
interpreter — on random inputs and exhaustively for small widths — and a
bundle whose bytes changed after save (tables, program, or the stored
attestation itself) must be rejected via the content hash before it can
reach the engine.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.dais import DaisProgram, compile_sequential
from repro.core.hgq_layers import HGQDense
from repro.core.lut_layers import LUTDense
from repro.core.quant import QuantConfig
from repro.kernels.lut_serve import (compile_program, input_code_bounds,
                                     verify_engine)
from repro.serve.api import EngineSpec, build
from repro.serve.artifact import (ArtifactError, load_artifact,
                                  save_artifact)

KEY = jax.random.PRNGKey(23)
IN_F, IN_I = 4, 2


def _engine(art, **spec_kw):
    """Bundle cold-start through the facade (gating is each test's own
    business here, so the spec skips the verify gate)."""
    return build(art, EngineSpec(verify="skip", **spec_kw)).engine


def _lut_stack(dims=(6, 5, 3), hidden=4, key=KEY):
    layers = [LUTDense(ci, co, hidden=hidden, use_batchnorm=(k == 0))
              for k, (ci, co) in enumerate(zip(dims[:-1], dims[1:]))]
    keys = jax.random.split(key, len(layers))
    params = [l.init(k) for l, k in zip(layers, keys)]
    return compile_sequential(layers, params, IN_F, IN_I)


def _narrow_cfg(overflow):
    return QuantConfig(granularity="element", signed=True, overflow=overflow,
                      init_f=1.0, init_i=1.0, min_f=-2, max_f=2,
                      min_i=-2, max_i=2)


# --------------------------------------------------------------------------- #
# DaisProgram wire format round trip
# --------------------------------------------------------------------------- #
def test_program_arrays_round_trip_lut():
    prog = _lut_stack()
    prog2 = DaisProgram.from_arrays(prog.to_arrays())
    assert [(i.op, i.args) for i in prog2.instrs] == \
           [(i.op, i.args) for i in prog.instrs]
    assert prog2.outputs == prog.outputs
    assert prog2.input_f == prog.input_f
    assert prog2.input_signed == prog.input_signed
    assert prog2.output_f == prog.output_f
    assert prog2.segments == prog.segments
    for lid, t in prog.tables.items():
        t2 = prog2.tables[lid]
        for fld in ("f_in", "i_in", "f_out", "i_out",
                    "in_width", "out_width", "codes"):
            np.testing.assert_array_equal(getattr(t2, fld), getattr(t, fld))
    lo, hi = input_code_bounds(prog)
    codes = np.random.default_rng(0).integers(lo, hi + 1, (128, len(lo)))
    np.testing.assert_array_equal(prog2.run(codes), prog.run(codes))


def test_program_arrays_round_trip_hybrid():
    """HGQ layers exercise CONST/CMUL/ADD/SAT-REQUANT arg shapes too."""
    h1 = HGQDense(5, 4, activation="relu")
    l1 = LUTDense(4, 3, hidden=4)
    k1, k2 = jax.random.split(KEY)
    prog = compile_sequential([h1, l1], [h1.init(k1), l1.init(k2)],
                              IN_F, IN_I)
    prog2 = DaisProgram.from_arrays(prog.to_arrays())
    assert [(i.op, i.args) for i in prog2.instrs] == \
           [(i.op, i.args) for i in prog.instrs]
    lo, hi = input_code_bounds(prog)
    codes = np.random.default_rng(1).integers(lo, hi + 1, (128, len(lo)))
    np.testing.assert_array_equal(prog2.run(codes), prog.run(codes))


def test_from_arrays_rejects_unknown_version():
    arrays = _lut_stack().to_arrays()
    arrays["version"] = np.asarray([99], np.int64)
    with pytest.raises(ValueError, match="version"):
        DaisProgram.from_arrays(arrays)


# --------------------------------------------------------------------------- #
# bundle round trip: save -> load -> run, bit-exact
# --------------------------------------------------------------------------- #
def test_bundle_round_trip_bit_exact_random(tmp_path):
    prog = _lut_stack()
    fresh = compile_program(prog)
    gate = verify_engine(fresh, prog, n_random=256)
    path = str(tmp_path / "model.npz")
    digest = save_artifact(path, prog, attestation=gate)

    art = load_artifact(path)
    assert art.content_hash == digest == art.meta["content_hash"]
    assert art.attestation["random"] == 256
    assert art.stages is not None            # pure LUT chain fuses
    loaded = _engine(art)
    assert loaded.fused

    lo, hi = input_code_bounds(prog)
    codes = np.random.default_rng(2).integers(lo, hi + 1, (512, len(lo)))
    ref = prog.run(codes)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(loaded.run(codes)), np.int64), ref)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(fresh.run(codes)), np.int64), ref)


def test_bundle_round_trip_bit_exact_exhaustive(tmp_path):
    """Narrow widths -> the loaded engine passes the full exhaustive gate."""
    layer = LUTDense(3, 4, hidden=4,
                     q_in=_narrow_cfg("WRAP"), q_out=_narrow_cfg("SAT"))
    prog = compile_sequential([layer], [layer.init(jax.random.PRNGKey(7))],
                              1, 1)
    path = str(tmp_path / "small.npz")
    save_artifact(path, prog)
    loaded = _engine(load_artifact(path))
    stats = verify_engine(loaded, prog, n_random=64, exhaustive_limit=1024)
    assert stats["exhaustive"] == 512        # 8**3 input cross-product


def test_hybrid_bundle_round_trips_with_fused_stages(tmp_path):
    """Hybrid programs fuse under v2: the bundle persists the composed
    stages (relu epilogue included) and the cold-started engine is
    bit-exact on the fused path."""
    h1 = HGQDense(5, 4, activation="relu")
    l1 = LUTDense(4, 3, hidden=4)
    k1, k2 = jax.random.split(KEY)
    prog = compile_sequential([h1, l1], [h1.init(k1), l1.init(k2)],
                              IN_F, IN_I)
    path = str(tmp_path / "hybrid.npz")
    save_artifact(path, prog)
    art = load_artifact(path)
    assert art.stages is not None and art.stages.n_stages() == 2
    loaded = _engine(art)
    assert loaded.path == "fused"
    verify_engine(loaded, art.prog, n_random=256)


def test_bundle_without_fused_payload_falls_back(tmp_path):
    """compose=False stores no fused payload; the loaded engine recomposes
    (or falls back) and still serves bit-exactly."""
    prog = _lut_stack()
    path = str(tmp_path / "nofuse.npz")
    save_artifact(path, prog, compose=False)
    art = load_artifact(path)
    assert art.stages is None
    loaded = _engine(art)       # recomposed from the program on load
    verify_engine(loaded, art.prog, n_random=256)


def _hybrid_conv_prog():
    from repro.core.hgq_layers import HGQConv1D
    from repro.core.lower import GraphInput, ModelGraph, WindowSum, lower
    from repro.core.lut_layers import LUTConv1D

    front = HGQConv1D(c_in=1, c_out=3, kernel=4, stride=4, activation="relu")
    lc = LUTConv1D(c_in=3, c_out=3, kernel=3, padding="SAME", hidden=4)
    head = LUTDense(3, 1, hidden=4)
    ks = jax.random.split(KEY, 3)
    params = [front.init(ks[0]), lc.init(ks[1]), head.init(ks[2])]
    graph = ModelGraph(GraphInput((16, 1), IN_F, IN_I),
                       [front, lc, head, WindowSum()])
    return lower(graph, params + [None])


def test_conv_hybrid_bundle_round_trip_v2(tmp_path):
    """Acceptance: the current bundle format round-trips the hybrid conv
    program (shared conv tables, hgq stage, window sum) bit-exactly on the
    fused path."""
    prog = _hybrid_conv_prog()
    fresh = compile_program(prog)
    gate = verify_engine(fresh, prog, n_random=256)
    path = str(tmp_path / "hybrid_conv.npz")
    save_artifact(path, prog, attestation=gate)

    art = load_artifact(path)
    assert art.meta["format_version"] == 3
    assert art.stages is not None and art.stages.n_stages() == 4
    loaded = _engine(art)
    assert loaded.path == "fused"

    lo, hi = input_code_bounds(prog)
    codes = np.random.default_rng(7).integers(lo, hi + 1, (256, len(lo)))
    ref = prog.run(codes)
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(loaded.run(codes)), np.int64), ref)


def test_v1_bundle_negotiated(tmp_path):
    """Backward compat: a v1 bundle (pre-site wire format, legacy fused
    layout) still loads; its fused payload is superseded, so stages are
    recomposed from the program and serving stays bit-exact."""
    from repro.serve.artifact import _bundle_digest

    prog = _lut_stack()
    arrays = {f"prog/{k}": v for k, v in prog.to_arrays().items()}
    # downgrade the program arrays to wire v1
    arrays["prog/version"] = np.asarray([1], np.int64)
    arrays["prog/seg_meta"] = arrays["prog/seg_meta"][:, :4]
    # legacy fused payload (v1 layout the v2 reader must ignore)
    arrays["fused/n_stages"] = np.asarray([1], np.int64)
    arrays["fused/table0"] = np.zeros((2, 2, 2), np.int64)
    meta_core = {"format_version": 1, "fused": True, "attestation": None}
    digest = _bundle_digest(arrays, meta_core)
    meta = {**meta_core, "content_hash": digest}
    arrays["meta_json"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8)
    path = str(tmp_path / "legacy.npz")
    np.savez(path, **arrays)

    art = load_artifact(path)
    assert art.meta["format_version"] == 1
    assert art.stages is None               # legacy fused layout dropped
    loaded = _engine(art)              # recomposes from the program
    verify_engine(loaded, art.prog, n_random=256)


# --------------------------------------------------------------------------- #
# tampering: any post-save modification fails the content hash
# --------------------------------------------------------------------------- #
def _rewrite(path, mutate):
    with np.load(path) as z:
        arrays = {k: z[k].copy() for k in z.files}
    mutate(arrays)
    np.savez(path, **arrays)


def test_tampered_table_rejected(tmp_path):
    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog)

    def flip_table_entry(arrays):
        key = next(k for k in arrays if k.startswith("prog/table")
                   and k.endswith("codes"))
        arrays[key][0, 0, 0] += 1
    _rewrite(path, flip_table_entry)
    with pytest.raises(ArtifactError, match="hash mismatch"):
        load_artifact(path)


def test_tampered_fused_stage_rejected(tmp_path):
    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog)

    def flip_fused(arrays):
        arrays["fused/stage0_table"][0, 0, 0] ^= 1
    _rewrite(path, flip_fused)
    with pytest.raises(ArtifactError, match="hash mismatch"):
        load_artifact(path)


def test_tampered_hybrid_bundle_rejected(tmp_path):
    """Hybrid v2 bundles stay tamper-evident: program tables, composed
    stage payloads, and epilogue params are all under the content hash."""
    prog = _hybrid_conv_prog()
    path = str(tmp_path / "hybrid_conv.npz")
    save_artifact(path, prog)
    for key_suffix in ("_gather", "_bias"):
        def flip(arrays, suffix=key_suffix):
            key = next(k for k in arrays if k.startswith("fused/stage")
                       and k.endswith(suffix))
            arrays[key].flat[0] += 1
        _rewrite(path, flip)
        with pytest.raises(ArtifactError, match="hash mismatch"):
            load_artifact(path)
        save_artifact(path, prog)        # restore for the next mutation


def test_forged_attestation_rejected(tmp_path):
    """--skip-verify-cached trusts the stored attestation, so editing it
    (without touching a single data array) must still fail the hash."""
    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog, attestation={"random": 16, "exhaustive": 0})

    def forge(arrays):
        meta = json.loads(bytes(arrays["meta_json"]).decode())
        meta["attestation"]["random"] = 10**9      # "trust me"
        arrays["meta_json"] = np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8)
    _rewrite(path, forge)
    with pytest.raises(ArtifactError, match="hash mismatch"):
        load_artifact(path)


# --------------------------------------------------------------------------- #
# tampering, round 2: a rewritten hash gets past the digest — the
# structural verifier is the next gate (ISSUE: don't trust prog/* arrays)
# --------------------------------------------------------------------------- #
def _rewrite_rehash(path, mutate):
    """Mutate arrays AND recompute the stored digest, as an adversary with
    write access would — the load must then fall through to the verifier."""
    from repro.serve.artifact import _bundle_digest

    with np.load(path) as z:
        arrays = {k: z[k].copy() for k in z.files}
    meta = json.loads(bytes(arrays.pop("meta_json")).decode())
    mutate(arrays)
    meta_core = {k: v for k, v in meta.items() if k != "content_hash"}
    meta["content_hash"] = _bundle_digest(arrays, meta_core)
    arrays["meta_json"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8)
    np.savez(path, **arrays)


def test_rehashed_out_of_range_register_rejected(tmp_path):
    from repro.core.dais import _OP_CODES

    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog)

    def dangling_arg(arrays):
        ops = arrays["prog/instr_op"]
        idx = int(np.flatnonzero(ops == _OP_CODES.index("REQUANT"))[0])
        arrays["prog/instr_args"][idx, 0] = 10**6    # register that never is
    _rewrite_rehash(path, dangling_arg)
    with pytest.raises(ArtifactError, match="structural verifier"):
        load_artifact(path)


def test_rehashed_oversized_llut_index_rejected(tmp_path):
    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog)

    def oversize(arrays):
        key = next(k for k in arrays if k.startswith("prog/table")
                   and k.endswith("_in_width"))
        arrays[key] = arrays[key] + 7    # 1 << m now exceeds codes.shape[2]
    _rewrite_rehash(path, oversize)
    with pytest.raises(ArtifactError, match="structural verifier"):
        load_artifact(path)


# --------------------------------------------------------------------------- #
# rtl attestation: bundles carry (and protect) the hardware-level proof
# --------------------------------------------------------------------------- #
def test_rtl_attestation_round_trips(tmp_path):
    """A bundle saved with an 'rtl' attestation entry returns it intact,
    and the stored Verilog hash matches what the loaded program re-emits —
    the bundle pins exactly WHICH hardware passed the three-way gate."""
    import hashlib

    from repro.core.rtl import emit_verilog, verify_rtl

    prog = _lut_stack(dims=(4, 4, 2))
    engine = compile_program(prog)
    gate = verify_engine(engine, prog, n_random=128)
    gate["rtl"] = verify_rtl(prog, engine=engine, n_random=64)
    path = str(tmp_path / "attested.npz")
    save_artifact(path, prog, attestation=gate)

    art = load_artifact(path)
    rtl = art.attestation["rtl"]
    assert rtl["verdict"] == "bit-exact"
    assert rtl["random"] == 64 and rtl["engine_path"] == engine.path
    assert rtl["verilog_sha256"] == hashlib.sha256(
        emit_verilog(art.prog).encode()).hexdigest()


def test_tampered_rtl_attestation_rejected(tmp_path):
    """Swapping the attested Verilog hash (e.g. to pass off different RTL
    as verified) breaks the bundle's content hash."""
    from repro.core.rtl import verify_rtl

    prog = _lut_stack(dims=(4, 4, 2))
    path = str(tmp_path / "attested.npz")
    save_artifact(path, prog,
                  attestation={"random": 16, "exhaustive": 0,
                               "rtl": verify_rtl(prog, n_random=16)})

    def swap_rtl_hash(arrays):
        meta = json.loads(bytes(arrays["meta_json"]).decode())
        meta["attestation"]["rtl"]["verilog_sha256"] = "0" * 64
        arrays["meta_json"] = np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8)
    _rewrite(path, swap_rtl_hash)
    with pytest.raises(ArtifactError, match="hash mismatch"):
        load_artifact(path)


def test_pre_rtl_bundles_still_load(tmp_path):
    """Bundles written before the rtl entry existed (attestation without
    'rtl', or no attestation at all) load and serve unchanged — the entry
    is free-form metadata, not a format bump."""
    prog = _lut_stack(dims=(4, 4, 2))
    path = str(tmp_path / "pre_rtl.npz")
    save_artifact(path, prog, attestation={"random": 32, "exhaustive": 0})
    art = load_artifact(path)
    assert art.meta["format_version"] == 3
    assert "rtl" not in art.attestation
    verify_engine(_engine(art), art.prog, n_random=128)

    save_artifact(path, prog)                # no attestation at all
    art = load_artifact(path)
    assert art.attestation is None
    verify_engine(_engine(art), art.prog, n_random=128)


def test_unreadable_and_versioned_bundles_rejected(tmp_path):
    garbage = tmp_path / "garbage.npz"
    garbage.write_bytes(b"not an npz at all")
    with pytest.raises(ArtifactError, match="cannot read"):
        load_artifact(str(garbage))

    prog = _lut_stack()
    path = str(tmp_path / "model.npz")
    save_artifact(path, prog)

    def bump_version(arrays):
        meta = json.loads(bytes(arrays["meta_json"]).decode())
        meta["format_version"] = 99
        arrays["meta_json"] = np.frombuffer(
            json.dumps(meta, sort_keys=True).encode(), np.uint8)
    _rewrite(path, bump_version)
    with pytest.raises(ArtifactError, match="format_version"):
        load_artifact(str(path))
