"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles,
plus gradchecks of the fused recompute backward against the einsum VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import fake_quant_ref, lut_dense_ref, lut_dense_train_ref

KEY = jax.random.PRNGKey(7)


def _lut_inputs(b, ci, h, co, dtype, key=KEY):
    ks = jax.random.split(key, 7)
    x = (jax.random.normal(ks[0], (b, ci)) * 3).astype(dtype)
    w0 = jax.random.normal(ks[1], (ci, h, co)).astype(jnp.float32)
    b0 = (jax.random.normal(ks[2], (ci, h, co)) * 0.5).astype(jnp.float32)
    wo = (jax.random.normal(ks[3], (ci, h, co)) * 0.3).astype(jnp.float32)
    bo = (jax.random.normal(ks[4], (ci, co)) * 0.1).astype(jnp.float32)
    fi = jax.random.randint(ks[5], (ci, co), 0, 7).astype(jnp.float32)
    ii = jnp.full((ci, co), 3.0)
    fo = jax.random.randint(ks[6], (ci, co), 0, 7).astype(jnp.float32)
    io = jnp.full((ci, co), 3.0)
    return x, w0, b0, wo, bo, fi, ii, fo, io


LUT_SHAPES = [
    (1, 1, 1, 1), (7, 3, 4, 5), (16, 16, 8, 20), (33, 5, 8, 19),
    (128, 16, 8, 5), (256, 4, 2, 128), (300, 7, 8, 130),
]


def _assert_lut_close(out, ref, fo):
    """Kernel and ref reduce over C_in in different orders; a pre-quant value
    sitting exactly on a rounding boundary may flip by one grid step.  Allow
    a vanishing fraction of single-step flips, bitwise match elsewhere."""
    out, ref = np.asarray(out), np.asarray(ref)
    diff = np.abs(out - ref)
    step = 2.0 ** -float(np.min(np.asarray(fo)))
    assert diff.max() <= step + 1e-5, diff.max()
    assert (diff > 1e-5).mean() < 1e-3, f"{(diff > 1e-5).mean():.2e} mismatch"


@pytest.mark.parametrize("b,ci,h,co", LUT_SHAPES)
def test_lut_dense_shape_sweep(b, ci, h, co):
    args = _lut_inputs(b, ci, h, co, jnp.float32)
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    _assert_lut_close(out, ref, args[7])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_dense_dtypes(dtype):
    args = _lut_inputs(24, 6, 8, 10, dtype)
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=(1e-5 if dtype == jnp.float32 else 0.3),
                               rtol=1e-2)


def test_lut_dense_backward_matches_einsum_grads():
    args = _lut_inputs(16, 4, 4, 6, jnp.float32)
    x, w0, b0, wo, bo, fi, ii, fo, io = args

    def loss_kernel(w0):
        return jnp.sum(ops.lut_dense(x, w0, b0, wo, bo, fi, ii, fo, io) ** 2)

    g = jax.grad(loss_kernel)(w0)
    assert g.shape == w0.shape
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.linalg.norm(g)) > 0


# --------------------------------------------------------------------------- #
# fused recompute backward vs. jax.grad of the einsum train-mode reference
# --------------------------------------------------------------------------- #
def _lut_train_inputs(b, ci, h, co, seed=11, pruned=False):
    """Like _lut_inputs but with negative widths mixed in when ``pruned``:
    f down to -4 with i=3 gives cells of total width <= 0 (pruned to zero)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    lo = -4 if pruned else 0
    x = (jax.random.normal(ks[0], (b, ci)) * 3).astype(jnp.float32)
    w0 = jax.random.normal(ks[1], (ci, h, co))
    b0 = jax.random.normal(ks[2], (ci, h, co)) * 0.5
    wo = jax.random.normal(ks[3], (ci, h, co)) * 0.3
    bo = jax.random.normal(ks[4], (ci, co)) * 0.1
    fi = jax.random.randint(ks[5], (ci, co), lo, 7).astype(jnp.float32)
    ii = jnp.full((ci, co), 3.0)
    fo = jax.random.randint(ks[6], (ci, co), lo, 7).astype(jnp.float32)
    io = jnp.full((ci, co), 3.0)
    cot = jax.random.normal(ks[7], (b, co))
    return (x, w0, b0, wo, bo, fi, ii, fo, io), cot


GRAD_NAMES = ("x", "w0", "b0", "w_out", "b_out", "f_in", "i_in", "f_out", "i_out")
# odd shapes exercise batch/C_out padding (tb=256, tco=128 defaults); the
# (300, 130) cell runs a 2x2 grid and the cross-tile grad accumulation
GRAD_SHAPES = [(7, 3, 4, 5, False), (16, 4, 4, 6, True), (33, 5, 8, 19, True),
               (300, 7, 4, 130, True)]


@pytest.mark.parametrize("b,ci,h,co,pruned", GRAD_SHAPES)
def test_fused_bwd_gradcheck_all_tensors(b, ci, h, co, pruned):
    """Fused VJP == jax.grad of the einsum reference for all 9 inputs.

    The loss is a fixed linear probe sum(out * cot) so the comparison isolates
    the backward: the cotangent entering both VJPs is bit-identical."""
    args, cot = _lut_train_inputs(b, ci, h, co, pruned=pruned)

    g_ref = jax.grad(lambda *a: jnp.sum(lut_dense_train_ref(*a) * cot),
                     argnums=tuple(range(9)))(*args)
    g_fus = jax.grad(lambda *a: jnp.sum(ops.lut_dense(*a) * cot),
                     argnums=tuple(range(9)))(*args)
    for name, gr, gf in zip(GRAD_NAMES, g_ref, g_fus):
        np.testing.assert_allclose(
            np.asarray(gf), np.asarray(gr), atol=1e-4, rtol=1e-4,
            err_msg=f"grad mismatch for {name} at shape {(b, ci, h, co)}")
    # WRAP input quantizer: i_in surrogate is identically zero
    np.testing.assert_array_equal(np.asarray(g_fus[6]), 0.0)
    if pruned:
        # cells with rounded width <= 0 contribute exactly zero bit-width grad
        alive_in = np.asarray(args[5] + args[6] + 1.0 > 0.0)
        assert np.all(np.asarray(g_fus[5])[~alive_in] == 0.0)


def test_fused_train_wrapper_continuous_widths():
    """lut_dense_train (continuous widths, clip + round-STE inside) matches
    grads of the einsum path built from core.quant's fake_quant chain."""
    from repro.core.quant import round_ste

    (x, w0, b0, wo, bo, fi, ii, fo, io), cot = _lut_train_inputs(24, 4, 4, 10)
    clip = ((-8.0, 12.0), (-8.0, 12.0))
    fi_c = fi + 0.31          # off-grid continuous parameters
    io_c = io - 0.27

    def fused(fi_c, io_c):
        y = ops.lut_dense_train(x, w0, b0, wo, bo, fi_c, ii, fo, io_c,
                                clip_in=clip, clip_out=clip)
        return jnp.sum(y * cot)

    def einsum(fi_c, io_c):
        r = lambda a: round_ste(jnp.clip(a, -8.0, 12.0))
        y = lut_dense_train_ref(x, w0, b0, wo, bo, r(fi_c), r(ii), r(fo), r(io_c))
        return jnp.sum(y * cot)

    gf = jax.grad(fused, argnums=(0, 1))(fi_c, io_c)
    gr = jax.grad(einsum, argnums=(0, 1))(fi_c, io_c)
    for name, a, b in zip(("f_in", "i_out"), gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4, err_msg=name)


FQ_SHAPES = [(1,), (5,), (128,), (130,), (8, 128), (3, 5, 7), (1000,), (2, 3, 129)]


@pytest.mark.parametrize("shape", FQ_SHAPES)
@pytest.mark.parametrize("mode", ["SAT", "WRAP"])
def test_fake_quant_shape_sweep(shape, mode):
    x = jax.random.normal(KEY, shape) * 6
    f = jnp.full(shape, 3.0)
    i = jnp.full(shape, 2.0)
    out = ops.fake_quant(x, f, i, overflow=mode)
    ref = fake_quant_ref(x, f, i, True, mode)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 40), ci=st.integers(1, 10), co=st.integers(1, 24),
       seed=st.integers(0, 1000))
def test_lut_dense_property_fuzz(b, ci, co, seed):
    args = _lut_inputs(b, ci, 4, co, jnp.float32, jax.random.PRNGKey(seed))
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    _assert_lut_close(out, ref, args[7])


@pytest.mark.parametrize("mode", ["SAT", "WRAP"])
def test_fake_quant_granularity_equivalence(mode):
    """Per-tensor / per-channel widths must produce bit-identical output to
    the fully-broadcast per-element form (the narrow forms ride along as one
    VMEM tile instead of tripling the op's HBM traffic)."""
    x = jax.random.normal(KEY, (37, 12)) * 6
    # per-tensor: scalar f/i vs full broadcast
    out_s = ops.fake_quant(x, jnp.asarray(3.0), jnp.asarray(2.0), overflow=mode)
    out_b = ops.fake_quant(x, jnp.full(x.shape, 3.0), jnp.full(x.shape, 2.0),
                           overflow=mode)
    np.testing.assert_array_equal(np.asarray(out_s), np.asarray(out_b))
    # per-channel: (C,) widths vs full broadcast, incl. pruned channels
    f = jax.random.randint(KEY, (12,), -2, 8).astype(jnp.float32)
    i = jax.random.randint(jax.random.PRNGKey(1), (12,), 0, 4).astype(jnp.float32)
    out_c = ops.fake_quant(x, f, i, overflow=mode)
    out_bc = ops.fake_quant(x, jnp.broadcast_to(f, x.shape),
                            jnp.broadcast_to(i, x.shape), overflow=mode)
    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_bc))
    np.testing.assert_array_equal(np.asarray(out_c),
                                  np.asarray(fake_quant_ref(x, f, i, True, mode)))
    # 3-D leading dims with a channel axis that needs lane padding
    x3 = jax.random.normal(KEY, (3, 5, 12)) * 4
    out3 = ops.fake_quant(x3, f, i, overflow=mode)
    np.testing.assert_array_equal(np.asarray(out3),
                                  np.asarray(fake_quant_ref(x3, f, i, True, mode)))


def test_fake_quant_heterogeneous_bits():
    x = jax.random.normal(KEY, (16, 16)) * 4
    f = jax.random.randint(KEY, (16, 16), -2, 8).astype(jnp.float32)
    i = jax.random.randint(jax.random.PRNGKey(1), (16, 16), 0, 4).astype(jnp.float32)
    for mode in ("SAT", "WRAP"):
        out = ops.fake_quant(x, f, i, overflow=mode)
        ref = fake_quant_ref(x, f, i, True, mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
