"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import fake_quant_ref, lut_dense_ref

KEY = jax.random.PRNGKey(7)


def _lut_inputs(b, ci, h, co, dtype, key=KEY):
    ks = jax.random.split(key, 7)
    x = (jax.random.normal(ks[0], (b, ci)) * 3).astype(dtype)
    w0 = jax.random.normal(ks[1], (ci, h, co)).astype(jnp.float32)
    b0 = (jax.random.normal(ks[2], (ci, h, co)) * 0.5).astype(jnp.float32)
    wo = (jax.random.normal(ks[3], (ci, h, co)) * 0.3).astype(jnp.float32)
    bo = (jax.random.normal(ks[4], (ci, co)) * 0.1).astype(jnp.float32)
    fi = jax.random.randint(ks[5], (ci, co), 0, 7).astype(jnp.float32)
    ii = jnp.full((ci, co), 3.0)
    fo = jax.random.randint(ks[6], (ci, co), 0, 7).astype(jnp.float32)
    io = jnp.full((ci, co), 3.0)
    return x, w0, b0, wo, bo, fi, ii, fo, io


LUT_SHAPES = [
    (1, 1, 1, 1), (7, 3, 4, 5), (16, 16, 8, 20), (33, 5, 8, 19),
    (128, 16, 8, 5), (256, 4, 2, 128), (300, 7, 8, 130),
]


def _assert_lut_close(out, ref, fo):
    """Kernel and ref reduce over C_in in different orders; a pre-quant value
    sitting exactly on a rounding boundary may flip by one grid step.  Allow
    a vanishing fraction of single-step flips, bitwise match elsewhere."""
    out, ref = np.asarray(out), np.asarray(ref)
    diff = np.abs(out - ref)
    step = 2.0 ** -float(np.min(np.asarray(fo)))
    assert diff.max() <= step + 1e-5, diff.max()
    assert (diff > 1e-5).mean() < 1e-3, f"{(diff > 1e-5).mean():.2e} mismatch"


@pytest.mark.parametrize("b,ci,h,co", LUT_SHAPES)
def test_lut_dense_shape_sweep(b, ci, h, co):
    args = _lut_inputs(b, ci, h, co, jnp.float32)
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    _assert_lut_close(out, ref, args[7])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_dense_dtypes(dtype):
    args = _lut_inputs(24, 6, 8, 10, dtype)
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=(1e-5 if dtype == jnp.float32 else 0.3),
                               rtol=1e-2)


def test_lut_dense_backward_matches_einsum_grads():
    args = _lut_inputs(16, 4, 4, 6, jnp.float32)
    x, w0, b0, wo, bo, fi, ii, fo, io = args

    def loss_kernel(w0):
        return jnp.sum(ops.lut_dense(x, w0, b0, wo, bo, fi, ii, fo, io) ** 2)

    g = jax.grad(loss_kernel)(w0)
    assert g.shape == w0.shape
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.linalg.norm(g)) > 0


FQ_SHAPES = [(1,), (5,), (128,), (130,), (8, 128), (3, 5, 7), (1000,), (2, 3, 129)]


@pytest.mark.parametrize("shape", FQ_SHAPES)
@pytest.mark.parametrize("mode", ["SAT", "WRAP"])
def test_fake_quant_shape_sweep(shape, mode):
    x = jax.random.normal(KEY, shape) * 6
    f = jnp.full(shape, 3.0)
    i = jnp.full(shape, 2.0)
    out = ops.fake_quant(x, f, i, overflow=mode)
    ref = fake_quant_ref(x, f, i, True, mode)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 40), ci=st.integers(1, 10), co=st.integers(1, 24),
       seed=st.integers(0, 1000))
def test_lut_dense_property_fuzz(b, ci, co, seed):
    args = _lut_inputs(b, ci, 4, co, jnp.float32, jax.random.PRNGKey(seed))
    ref = lut_dense_ref(*args)
    out = ops.lut_dense(*args)
    _assert_lut_close(out, ref, args[7])


def test_fake_quant_heterogeneous_bits():
    x = jax.random.normal(KEY, (16, 16)) * 4
    f = jax.random.randint(KEY, (16, 16), -2, 8).astype(jnp.float32)
    i = jax.random.randint(jax.random.PRNGKey(1), (16, 16), 0, 4).astype(jnp.float32)
    for mode in ("SAT", "WRAP"):
        out = ops.fake_quant(x, f, i, overflow=mode)
        ref = fake_quant_ref(x, f, i, True, mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
