"""Quickstart: the full HGQ-LUT flow of Fig. 1 in ~60 seconds on CPU.

1. build a 2-layer LUT-Dense classifier for the (synthetic) JSC-HLF task,
2. train with the β-weighted EBOPs objective (automatic bit-width search +
   0-bit pruning),
3. extract truth tables, lower to DAIS, emit Verilog,
4. verify DAIS interpreter == JAX eval **bit-exactly**,
5. simulate the emitted Verilog and attest it bit-exact against the
   interpreter (the hardware-verification gate),
6. report accuracy / EBOPs / estimated FPGA LUTs.

Run:  PYTHONPATH=src python examples/quickstart.py [--smoke | --steps N]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dais import compile_sequential
from repro.core.ebops import BetaSchedule, estimate_luts
from repro.core.lut_layers import LUTDense
from repro.core.quant import int_to_float, quantize_to_int
from repro.core.rtl import emit_verilog, verify_rtl
from repro.data.synthetic import jsc_hlf
from repro.nn.base import merge_aux
from repro.optim.adam import AdamConfig, adam_init, adam_update, cosine_restarts

BATCH = 1024
IN_F, IN_I = 4, 3  # input fixed-point format (paper: no clamping needed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI: few steps, small data, "
                         "same train -> compile -> verify pipeline")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the training step count")
    args = ap.parse_args(argv)
    steps = args.steps if args.steps is not None else (30 if args.smoke else 600)
    n_train, n_test = (2000, 500) if args.smoke else (20000, 5000)
    batch = 256 if args.smoke else BATCH

    # ---------------------------------------------------------------- data
    xtr, ytr = jsc_hlf(seed=0, n=n_train, split="train")
    xte, yte = jsc_hlf(seed=0, n=n_test, split="test")
    # inputs arrive pre-quantized, as they would from the detector front-end
    xtr = int_to_float(quantize_to_int(xtr, IN_F, IN_I, True, "SAT"), IN_F)
    xte = int_to_float(quantize_to_int(xte, IN_F, IN_I, True, "SAT"), IN_F)

    # --------------------------------------------------------------- model
    l1 = LUTDense(16, 20, hidden=8, use_batchnorm=True)
    l2 = LUTDense(20, 5, hidden=8)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {"l1": l1.init(k1), "l2": l2.init(k2)}
    opt = adam_init(params)
    beta = BetaSchedule(5e-7, 1e-4, steps)     # paper §V-A HLF JSC range
    acfg = AdamConfig(lr=3e-3)
    sched = cosine_restarts(3e-3, first_period=max(steps // 2, 1),
                            warmup=min(30, steps // 2))

    @jax.jit
    def step(params, opt, x, y, s):
        def loss_fn(p):
            h, a1 = l1.apply(p["l1"], x, train=True)
            logits, a2 = l2.apply(p["l2"], h, train=True)
            aux = merge_aux(a1, a2)
            ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])
            return ce + beta(s) * aux.ebops, (aux, ce)

        (_, (aux, ce)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(params, grads, opt, acfg, sched)
        # merge batch-norm moving-stat updates (non-gradient state)
        for path, val in aux.updates.items():
            params["l1"][path] = val
        return params, opt, ce, aux.ebops

    t0 = time.time()
    rng = np.random.default_rng(0)
    for s in range(steps):
        idx = rng.integers(0, len(xtr), batch)
        params, opt, ce, ebops = step(params, opt, jnp.asarray(xtr[idx]),
                                      jnp.asarray(ytr[idx]), jnp.asarray(s))
        if s % 100 == 0:
            print(f"step {s:4d}  ce={float(ce):.4f}  ebops={float(ebops):9.1f}")
    print(f"training: {time.time()-t0:.1f}s for {steps} steps")

    # ----------------------------------------------------- evaluate (JAX)
    h, _ = l1.apply(params["l1"], jnp.asarray(xte), train=False)
    logits, _ = l2.apply(params["l2"], h, train=False)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yte)))
    eb = float(ebops)
    print(f"test accuracy: {acc:.4f}   EBOPs: {eb:.0f}   "
          f"estimated FPGA LUTs: {estimate_luts(eb):.0f}")

    # --------------------------------------- compile to DAIS + RTL, verify
    t0 = time.time()
    prog = compile_sequential([l1, l2], [params["l1"], params["l2"]], IN_F, IN_I)
    print(f"DAIS lowering: {time.time()-t0:.2f}s, {prog.n_instrs()} instrs "
          f"{prog.count_ops()}")

    # static analysis: verifier + per-register proven value ranges; the
    # proven widths drive engine dtype selection and Pallas lane narrowing
    from repro.launch.lint import lint_program
    lint_program(prog, name="quickstart model")
    dais_out = prog.run_float(xte[:2048])
    jax_out = np.asarray(logits[:2048], np.float64)
    exact = np.abs(dais_out - jax_out).max()
    print(f"bit-exact check (DAIS vs JAX eval): max|Δ| = {exact} "
          f"{'✓ BIT-EXACT' if exact == 0 else '✗ MISMATCH'}")
    dais_acc = float(np.mean(np.argmax(dais_out, -1) == yte[:2048]))
    print(f"DAIS-interpreted accuracy: {dais_acc:.4f}")

    verilog = emit_verilog(prog)
    open("/tmp/hgq_lut_model.v", "w").write(verilog)
    print(f"emitted Verilog: /tmp/hgq_lut_model.v ({len(verilog.splitlines())} lines)")

    # ------------------------------------------- simulate the emitted RTL
    t0 = time.time()
    att = verify_rtl(prog, verilog, n_random=128 if args.smoke else 512)
    print(f"RTL simulation: {att['verdict']} vs the DAIS interpreter over "
          f"{att['random']} random + {att['exhaustive']} exhaustive rows "
          f"({att['n_wires']} wires, sha256 {att['verilog_sha256'][:12]}, "
          f"{time.time()-t0:.1f}s)")
    assert exact == 0.0


if __name__ == "__main__":
    main()
