"""Accuracy–resource Pareto frontier from a SINGLE training run (paper §V-A).

The β trade-off parameter ramps exponentially during training
(5e-7 → 1e-3 for HLF JSC); snapshots taken along the ramp trace the
accuracy-vs-EBOPs frontier — no per-point retraining, which is the
methodological core of HGQ(-LUT)'s "automatic exploration of
accuracy-resource trade-offs without manual bit-width tuning".

This example stops at the *training-side* frontier (accuracy vs EBOPs).
The full pipeline version — snapshots checkpointed, every point compiled
through dead-cell elimination and the bit-exact engine gate, the frontier
written to BENCH_pareto.json, and a selected point served through the
artifact + scheduler path — is ``python -m repro.launch.pareto``.

Run:  PYTHONPATH=src python examples/pareto_sweep.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ebops import BetaSchedule, estimate_luts
from repro.core.lut_layers import LUTDense
from repro.core.quant import int_to_float, quantize_to_int
from repro.data.synthetic import jsc_hlf
from repro.nn.base import merge_aux
from repro.optim.adam import AdamConfig, adam_init, adam_update, cosine_restarts

STEPS = 1500
BATCH = 1024
SNAP_EVERY = 150
IN_F, IN_I = 4, 3


def main():
    xtr, ytr = jsc_hlf(0, 20000, "train")
    xval, yval = jsc_hlf(0, 5000, "val")
    xte, yte = jsc_hlf(0, 5000, "test")
    q = lambda x: int_to_float(quantize_to_int(x, IN_F, IN_I, True, "SAT"), IN_F)
    xtr, xval, xte = q(xtr), q(xval), q(xte)

    l1 = LUTDense(16, 20, hidden=8, use_batchnorm=True)
    l2 = LUTDense(20, 5, hidden=8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    params = {"l1": l1.init(k1), "l2": l2.init(k2)}
    opt = adam_init(params)
    # paper's HLF JSC range is 5e-7 → 1e-3; on the synthetic analogue the
    # frontier's informative span ends nearer 1e-4 (β=1e-3 prunes to chance)
    beta = BetaSchedule(5e-7, 1.5e-4, STEPS)
    acfg = AdamConfig(lr=3e-3)
    sched = cosine_restarts(3e-3, first_period=STEPS // 3, warmup=30)

    @jax.jit
    def step(params, opt, x, y, s):
        def loss_fn(p):
            h, a1 = l1.apply(p["l1"], x, train=True)
            logits, a2 = l2.apply(p["l2"], h, train=True)
            aux = merge_aux(a1, a2)
            ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(x.shape[0]), y])
            return ce + beta(s) * aux.ebops, aux
        (_, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(params, grads, opt, acfg, sched)
        for path, val in aux.updates.items():
            params["l1"][path] = val
        return params, opt, aux.ebops

    @jax.jit
    def evaluate(params, x, y):
        h, _ = l1.apply(params["l1"], x, train=False)
        logits, _ = l2.apply(params["l2"], h, train=False)
        return jnp.mean(jnp.argmax(logits, -1) == y)

    rng = np.random.default_rng(0)
    frontier = []
    t0 = time.time()
    for s in range(STEPS):
        idx = rng.integers(0, len(xtr), BATCH)
        params, opt, ebops = step(params, opt, jnp.asarray(xtr[idx]),
                                  jnp.asarray(ytr[idx]), jnp.asarray(s))
        if (s + 1) % SNAP_EVERY == 0:
            val_acc = float(evaluate(params, jnp.asarray(xval), jnp.asarray(yval)))
            test_acc = float(evaluate(params, jnp.asarray(xte), jnp.asarray(yte)))
            eb = float(ebops)
            frontier.append((s + 1, float(beta(jnp.asarray(s))), eb,
                             estimate_luts(eb), val_acc, test_acc))
            print(f"step {s+1:5d}  beta={frontier[-1][1]:.2e}  "
                  f"EBOPs={eb:9.1f}  est.LUTs={frontier[-1][3]:8.0f}  "
                  f"val={val_acc:.4f}  test={test_acc:.4f}", flush=True)

    print(f"\nsweep: {time.time()-t0:.0f}s.  Pareto points (selected on val):")
    best = {}
    for s, b, eb, luts, va, ta in frontier:
        key = round(np.log10(max(luts, 1)), 1)
        if key not in best or va > best[key][4]:
            best[key] = (s, b, eb, luts, va, ta)
    print(f"{'LUTs':>9s} {'EBOPs':>9s} {'val':>7s} {'test':>7s}")
    for key in sorted(best):
        s, b, eb, luts, va, ta = best[key]
        print(f"{luts:9.0f} {eb:9.0f} {va:7.4f} {ta:7.4f}")


if __name__ == "__main__":
    main()
