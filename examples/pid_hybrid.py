"""CEPC gas-detector PID via cluster counting (paper §V-F, Fig. 4-5).

Hybrid architecture exactly as the paper prescribes (the canonical spec
lives in ``repro.models.pid``): one conventional (matmul) conv layer
projects each 20-sample ADC patch to 8 features — feeding 12-bit waveforms
straight into LUT layers would blow the area budget — followed by LUT-Conv
layers, a time-independent LUT head, and window-count accumulation.
Trained with a FIXED β = 1e-7 (single target design point, <10k LUTs).

The observable is the kaon/pion *separation power*
S = (μ_K − μ_π) / ((σ_K + σ_π)/2) on the predicted cluster counts.

After training, the full deployment chain runs end-to-end: the hybrid
graph lowers to one DAIS program (``core/lower.py`` — the conv layers
share one table set across all spatial sites), the accelerator engine
compiles on the fused shared-table path and passes the bit-exactness gate,
the async micro-batching scheduler serves individual waveform requests
bit-exactly, and the same program is emitted as Verilog and simulated
(``core/rtl_sim.py``) for a three-way bit-exact attestation: RTL sim ==
DAIS interpreter == accelerator engine.

Run:  PYTHONPATH=src python examples/pid_hybrid.py [--smoke | --steps N]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ebops import estimate_luts
from repro.core.lower import lower
from repro.core.quant import int_to_float, quantize_to_int
from repro.core.rtl import emit_verilog, verify_rtl
from repro.data.synthetic import cepc_waveform
from repro.models.pid import IN_F, IN_I, build_pid_graph, build_pid_layers
from repro.serve import api as serve_api
from repro.nn.base import merge_aux
from repro.optim.adam import AdamConfig, adam_init, adam_update, cosine_restarts

BETA = 1e-7          # paper: fixed beta, budget < 10k LUTs


def forward(layers, params, wf, train):
    front, lc1, lc2, head = layers
    x = wf[..., None]                                   # (B, T, 1)
    h, a0 = front.apply(params["front"], x, train=train)   # (B, T/20, 8)
    h, a1 = lc1.apply(params["lc1"], h, train=train)
    h, a2 = lc2.apply(params["lc2"], h, train=train)
    counts, a3 = head.apply(params["head"], h, train=train)  # (B, W, 1)
    return counts[..., 0], merge_aux(a0, a1, a2, a3)


def separation(pred_counts, species):
    tot = np.asarray(pred_counts)
    if tot.ndim > 1:
        tot = tot.sum(axis=1)
    k, p = tot[species == 1], tot[species == 0]
    return (k.mean() - p.mean()) / ((k.std() + p.std()) / 2 + 1e-9)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale run for CI: few steps, short "
                         "waveforms, same end-to-end pipeline")
    ap.add_argument("--steps", type=int, default=None,
                    help="override the training step count")
    args = ap.parse_args(argv)

    steps = args.steps if args.steps is not None else (8 if args.smoke else 500)
    n_train, n_test = (96, 48) if args.smoke else (1200, 400)
    wf_len = 200 if args.smoke else 600      # shortened waveforms (CPU-friendly)
    ctx = 60 if args.smoke else 100          # compiled-program context samples
    batch = 64 if args.smoke else 128

    wf_tr, cnt_tr, sp_tr = cepc_waveform(0, n_train, wf_len, "train")
    wf_te, cnt_te, sp_te = cepc_waveform(0, n_test, wf_len, "test")
    # inputs arrive on the 12-bit unsigned ADC grid, as from the detector
    wf_tr = int_to_float(quantize_to_int(wf_tr, IN_F, IN_I, False, "SAT"), IN_F)
    wf_te = int_to_float(quantize_to_int(wf_te, IN_F, IN_I, False, "SAT"), IN_F)

    layers = build_pid_layers()
    front, lc1, lc2, head = layers
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"front": front.init(ks[0]), "lc1": lc1.init(ks[1]),
              "lc2": lc2.init(ks[2]), "head": head.init(ks[3])}
    opt = adam_init(params)
    acfg = AdamConfig(lr=2e-3)
    sched = cosine_restarts(2e-3, first_period=steps, warmup=min(20, steps // 2))

    @jax.jit
    def step(params, opt, wf, cnt):
        def loss_fn(p):
            pred, aux = forward(layers, p, wf, True)
            mse = jnp.mean((pred - cnt) ** 2)
            return mse + BETA * aux.ebops, (aux, mse)
        (_, (aux, mse)), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(params, g, opt, acfg, sched)
        return params, opt, mse, aux.ebops

    rng = np.random.default_rng(0)
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, n_train, batch)
        params, opt, mse, ebops = step(params, opt, jnp.asarray(wf_tr[idx]),
                                       jnp.asarray(cnt_tr[idx]))
        if s % 100 == 0:
            print(f"step {s:4d}  mse={float(mse):.4f}  ebops={float(ebops):.3g}",
                  flush=True)
    print(f"training {time.time()-t0:.0f}s for {steps} steps")

    pred, aux = forward(layers, params, jnp.asarray(wf_te), False)
    pred = np.asarray(pred)
    s_pred = separation(pred, sp_te)
    s_true = separation(cnt_te, sp_te)
    eb = float(aux.ebops)
    print(f"\nseparation power: model={s_pred:.3f}  "
          f"(truth-count reference={s_true:.3f})")
    print(f"EBOPs={eb:.0f}  est. LUTs={estimate_luts(eb):.0f} "
          f"(paper budget: <10k)")
    resid = np.abs(pred.sum(1) - cnt_te.sum(1)).mean()
    print(f"mean |count error| per waveform: {resid:.2f}")
    if not args.smoke:
        assert s_pred > 0.5 * s_true, "model separation too weak"

    # ---------------------------------------------- compile the hybrid graph
    t0 = time.time()
    graph = build_pid_graph(layers, n_samples=ctx)
    params_list = [params["front"], params["lc1"], params["lc2"],
                   params["head"], None]
    prog = lower(graph, params_list)
    n_llut = prog.count_ops().get("LLUT", 0)
    n_cells = sum(t.n_luts() for t in prog.tables.values())
    print(f"\nDAIS lowering ({ctx}-sample context): {time.time()-t0:.2f}s, "
          f"{prog.n_instrs()} instrs, {len(prog.tables)} shared table sets "
          f"({n_cells} live cells driving {n_llut} LLUT sites)")

    # static analysis: verifier + per-register proven value ranges; the
    # proven widths drive engine dtype selection and Pallas lane narrowing
    from repro.launch.lint import lint_program
    lint_program(prog, name=f"pid-hybrid ctx={ctx}")

    # trained bit-widths can push transients past int32; the engine then
    # needs the x64 path — sized from the proven engine_width bound, which
    # is often narrower than the conservative required_width
    from repro.kernels.lut_serve import engine_width
    ew = engine_width(prog)
    if ew > 30 and not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)
        print(f"(enabled x64: program needs {ew}-bit transients)")

    # ----------------------------- accelerator engine + bit-exactness gate
    # one EngineSpec = preferred lowering + require-flag + verify policy;
    # require="fused" turns a shared-table downgrade into a hard error
    t0 = time.time()
    built = serve_api.build(prog, serve_api.EngineSpec(
        require="fused", n_random=256 if args.smoke else 1024))
    engine, gate = built.engine, built.attestation
    print(f"engine: path={engine.path} ({engine.n_groups} shared-table "
          f"stages), bit-exact gate PASSED on {gate['random']} random + "
          f"{gate['exhaustive']} exhaustive rows ({time.time()-t0:.2f}s)")

    # JAX eval vs compiled integers: the only deltas left are the frontend's
    # float32 accumulation and the bias grid rounding — report them
    ctx_wf = wf_te[:, :ctx]
    jax_pred, _ = forward(layers, params, jnp.asarray(ctx_wf), False)
    jax_counts = np.asarray(jax_pred, np.float64).sum(axis=1)
    dais_counts = prog.run_float(ctx_wf)[:, 0]
    dq = np.abs(jax_counts - dais_counts).max()
    print(f"JAX eval vs DAIS integers on the {ctx}-sample context: "
          f"max|Δ| = {dq:.3g} (bias grid rounding)")
    assert dq < 0.5, "compiled program diverged from the trained model"

    # --------------------------- serve individual requests, bit-exactly
    from repro.serve.scheduler import MicroBatcher, ServeConfig

    codes = quantize_to_int(ctx_wf, IN_F, IN_I, False, "SAT")
    ref = prog.run(codes)
    with MicroBatcher(engine, ServeConfig(max_batch=16)) as batcher:
        futures = batcher.submit_many(codes)
        out = np.stack([f.result(timeout=120) for f in futures])
        stats = batcher.stats()
    np.testing.assert_array_equal(out.astype(np.int64), ref)
    print(f"scheduler served {stats.n_requests} waveform requests "
          f"bit-exactly: p50={stats.p50_ms:.2f} ms "
          f"p99={stats.p99_ms:.2f} ms "
          f"(batches={stats.n_batches})")

    # ------------------------------- emit Verilog + three-way attestation
    verilog = emit_verilog(prog, name="pid_hybrid")
    path = "/tmp/pid_hybrid.v"
    open(path, "w").write(verilog)
    print(f"emitted Verilog: {path} ({len(verilog.splitlines())} lines, "
          f"one case-function per shared table cell)")
    t0 = time.time()
    att = verify_rtl(prog, verilog, engine=engine,
                     n_random=64 if args.smoke else 256)
    print(f"RTL simulation: {att['verdict']} three ways (RTL sim == DAIS "
          f"interpreter == {att['engine_path']} engine) over {att['random']} "
          f"random + {att['exhaustive']} exhaustive rows ({att['n_wires']} "
          f"wires, {time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
