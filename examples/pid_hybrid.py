"""CEPC gas-detector PID via cluster counting (paper §V-F, Fig. 4-5).

Hybrid architecture exactly as the paper prescribes: one conventional
(matmul) conv layer projects each 20-sample ADC patch to 8 features —
feeding 12-bit waveforms straight into LUT layers would blow the area
budget — followed by LUT-Conv layers, a time-independent LUT head, and
window-count accumulation.  Trained with a FIXED β = 1e-7 (single target
design point, <10k LUTs).

The observable is the kaon/pion *separation power*
S = (μ_K − μ_π) / ((σ_K + σ_π)/2) on the predicted cluster counts.

Run:  PYTHONPATH=src python examples/pid_hybrid.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ebops import estimate_luts
from repro.core.hgq_layers import HGQConv1D
from repro.core.lut_layers import LUTConv1D, LUTDense
from repro.data.synthetic import cepc_waveform
from repro.nn.base import merge_aux
from repro.optim.adam import AdamConfig, adam_init, adam_update, cosine_restarts

WINDOW = 20          # samples per DAQ cycle (256-bit bus / 12-bit samples)
CTX = 60             # model sees 60 samples to predict one 20-sample window
STEPS = 500
BETA = 1e-7          # paper: fixed beta, budget < 10k LUTs
N_TRAIN, N_TEST = 1200, 400
LEN = 600            # shortened waveforms (same structure, CPU-friendly)


def build():
    front = HGQConv1D(c_in=1, c_out=8, kernel=WINDOW, stride=WINDOW,
                      activation="relu")          # conventional conv frontend
    lc1 = LUTConv1D(c_in=8, c_out=8, kernel=3, padding="SAME", hidden=8)
    lc2 = LUTConv1D(c_in=8, c_out=4, kernel=3, padding="SAME", hidden=8)
    head = LUTDense(4, 1, hidden=8)               # per-window count regressor
    return front, lc1, lc2, head


def forward(layers, params, wf, train):
    front, lc1, lc2, head = layers
    x = wf[..., None]                                   # (B, T, 1)
    h, a0 = front.apply(params["front"], x, train=train)   # (B, T/20, 8)
    h, a1 = lc1.apply(params["lc1"], h, train=train)
    h, a2 = lc2.apply(params["lc2"], h, train=train)
    counts, a3 = head.apply(params["head"], h, train=train)  # (B, W, 1)
    return counts[..., 0], merge_aux(a0, a1, a2, a3)


def separation(pred_counts, species):
    tot = pred_counts.sum(axis=1)
    k, p = tot[species == 1], tot[species == 0]
    return (k.mean() - p.mean()) / ((k.std() + p.std()) / 2 + 1e-9)


def main():
    wf_tr, cnt_tr, sp_tr = cepc_waveform(0, N_TRAIN, LEN, "train")
    wf_te, cnt_te, sp_te = cepc_waveform(0, N_TEST, LEN, "test")

    layers = build()
    front, lc1, lc2, head = layers
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"front": front.init(ks[0]), "lc1": lc1.init(ks[1]),
              "lc2": lc2.init(ks[2]), "head": head.init(ks[3])}
    opt = adam_init(params)
    acfg = AdamConfig(lr=2e-3)
    sched = cosine_restarts(2e-3, first_period=STEPS, warmup=20)

    @jax.jit
    def step(params, opt, wf, cnt):
        def loss_fn(p):
            pred, aux = forward(layers, p, wf, True)
            mse = jnp.mean((pred - cnt) ** 2)
            return mse + BETA * aux.ebops, (aux, mse)
        (_, (aux, mse)), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, _ = adam_update(params, g, opt, acfg, sched)
        return params, opt, mse, aux.ebops

    rng = np.random.default_rng(0)
    t0 = time.time()
    for s in range(STEPS):
        idx = rng.integers(0, N_TRAIN, 128)
        params, opt, mse, ebops = step(params, opt, jnp.asarray(wf_tr[idx]),
                                       jnp.asarray(cnt_tr[idx]))
        if s % 100 == 0:
            print(f"step {s:4d}  mse={float(mse):.4f}  ebops={float(ebops):.3g}",
                  flush=True)
    print(f"training {time.time()-t0:.0f}s")

    pred, aux = forward(layers, params, jnp.asarray(wf_te), False)
    pred = np.asarray(pred)
    s_pred = separation(pred, sp_te)
    s_true = separation(cnt_te, sp_te)
    eb = float(aux.ebops)
    print(f"\nseparation power: model={s_pred:.3f}  "
          f"(truth-count reference={s_true:.3f})")
    print(f"EBOPs={eb:.0f}  est. LUTs={estimate_luts(eb):.0f} "
          f"(paper budget: <10k)")
    resid = np.abs(pred.sum(1) - cnt_te.sum(1)).mean()
    print(f"mean |count error| per waveform: {resid:.2f}")
    assert s_pred > 0.5 * s_true, "model separation too weak"


if __name__ == "__main__":
    main()
