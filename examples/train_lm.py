"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on whatever devices exist: config →
model zoo → sharded train step → β-scheduled HGQ quantization → async
checkpoints → restart-resume.  This is the same code path the 512-chip
dry-run lowers; only the mesh differs.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.ebops import BetaSchedule
from repro.ckpt.store import CheckpointStore
from repro.data.synthetic import lm_batch
from repro.models.registry import build_model
from repro.optim.adam import AdamConfig, cosine_restarts
from repro.train.loop import chunked_train
from repro.train.steps import TrainHParams, init_state, make_train_step

# ~106M parameters: glu(3*640*2560)*10 + attn(4*640^2)*10 + embed 2*32k*640
LM100M = ArchConfig(
    name="lm100m", family="lm",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=2560, vocab=32000,
    qk_norm=True, mlp_type="glu", act="silu",
    quant="hgq",            # the paper's technique as a first-class feature
    q_chunk=64,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    model = build_model(LM100M)
    from repro.nn.params import count_params
    print(f"[train_lm] {count_params(model.defs())/1e6:.1f}M parameters")

    hp = TrainHParams(
        adam=AdamConfig(lr=6e-4, weight_decay=0.01),
        beta=BetaSchedule(1e-12, 1e-10, args.steps),  # gentle EBOPs pressure
        lr_schedule=cosine_restarts(6e-4, first_period=args.steps, warmup=20),
    )
    raw_step, _ = make_train_step(model, mesh=None, hp=hp, jit=False)
    params, opt = init_state(model, jax.random.PRNGKey(0))
    store = CheckpointStore(args.ckpt_dir, keep=2)
    start = 0
    if store.latest_step() is not None:
        params, opt, man = store.restore(params, opt)
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
        start = man["step"]
        print(f"[train_lm] resumed from step {start}")

    def get_batch(step: int) -> dict:
        return dict(lm_batch(0, step, args.batch, args.seq, LM100M.vocab))

    losses = []
    t0 = time.time()
    # scan-chunked driver (train/loop.py): K steps per jitted call, batches
    # prefetched on a background thread; chunks end on the checkpoint cadence
    for res in chunked_train(raw_step, params, opt, get_batch,
                             start, args.steps, chunk_steps=10,
                             boundaries=range(100, args.steps, 100)):
        params, opt = res.params, res.opt_state
        losses.extend(float(v) for v in res.metrics["ce"])
        for i in range(res.k):
            step = res.step + i
            if step % 20 == 0:
                dt = (time.time() - t0) / (step - start + 1)
                print(f"step {step:4d}  ce={float(res.metrics['ce'][i]):.4f}  "
                      f"ebops={float(res.metrics['ebops'][i]):.3g}  "
                      f"{dt:.2f}s/step", flush=True)
        end = res.step + res.k
        if end % 100 == 0:
            store.save(end, params, opt)
    store.wait()
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"[train_lm] ce {first:.3f} -> {last:.3f} over steps {start}..{args.steps} "
          f"({(time.time()-t0)/60:.1f} min)")
    if start == 0:
        assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
