"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full production stack on whatever devices exist: config →
model zoo → sharded train step → β-scheduled HGQ quantization → async
checkpoints → restart-resume.  This is the same code path the 512-chip
dry-run lowers; only the mesh differs.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.ebops import BetaSchedule
from repro.ckpt.store import CheckpointStore
from repro.data.synthetic import lm_batch
from repro.models.registry import build_model
from repro.optim.adam import AdamConfig, cosine_restarts
from repro.train.steps import TrainHParams, init_state, make_train_step

# ~106M parameters: glu(3*640*2560)*10 + attn(4*640^2)*10 + embed 2*32k*640
LM100M = ArchConfig(
    name="lm100m", family="lm",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=2560, vocab=32000,
    qk_norm=True, mlp_type="glu", act="silu",
    quant="hgq",            # the paper's technique as a first-class feature
    q_chunk=64,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    model = build_model(LM100M)
    from repro.nn.params import count_params
    print(f"[train_lm] {count_params(model.defs())/1e6:.1f}M parameters")

    hp = TrainHParams(
        adam=AdamConfig(lr=6e-4, weight_decay=0.01),
        beta=BetaSchedule(1e-12, 1e-10, args.steps),  # gentle EBOPs pressure
        lr_schedule=cosine_restarts(6e-4, first_period=args.steps, warmup=20),
    )
    step_fn, _ = make_train_step(model, mesh=None, hp=hp)
    params, opt = init_state(model, jax.random.PRNGKey(0))
    store = CheckpointStore(args.ckpt_dir, keep=2)
    start = 0
    if store.latest_step() is not None:
        params, opt, man = store.restore(params, opt)
        params = jax.tree.map(jnp.asarray, params)
        opt = jax.tree.map(jnp.asarray, opt)
        start = man["step"]
        print(f"[train_lm] resumed from step {start}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in
                 lm_batch(0, step, args.batch, args.seq, LM100M.vocab).items()}
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["ce"]))
        if step % 20 == 0:
            dt = (time.time() - t0) / (step - start + 1)
            print(f"step {step:4d}  ce={losses[-1]:.4f}  "
                  f"ebops={float(metrics['ebops']):.3g}  {dt:.2f}s/step",
                  flush=True)
        if (step + 1) % 100 == 0:
            store.save(step + 1, params, opt)
    store.wait()
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"[train_lm] ce {first:.3f} -> {last:.3f} over steps {start}..{args.steps} "
          f"({(time.time()-t0)/60:.1f} min)")
    if start == 0:
        assert last < first, "loss did not improve"


if __name__ == "__main__":
    main()
